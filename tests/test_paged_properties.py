"""Property/fuzz suite for the refcounted ``BlockAllocator``.

The allocator is the trust anchor of the serving stack: every page the
device writes is routed by block tables whose ids come from here, and
the prefix cache multiplies how many owners can point at one page.  The
suite drives random interleavings of the five lifecycle operations —
``alloc`` / ``fork`` / ``cow`` / ``free_pages`` / ``free_request`` —
against an independent model of who-holds-what, checking after *every*
step that nothing leaks and nothing double-frees:

    free + distinct(live owners' pages) == num_pages

plus refcount-vs-holders agreement (``BlockAllocator.check``).

Two drivers: a hypothesis ``RuleBasedStateMachine`` (shrinking,
>=1000 examples, skipped when hypothesis is absent) and a seeded
numpy random walk of the same rules that always runs, so tier-1 keeps
fuzzing the invariant even on environments without hypothesis.
"""
import numpy as np
import pytest

from repro.serving.paged import BlockAllocator

N_PAGES = 24


class AllocModel:
    """Reference model: owner -> ordered page list, mirrored by hand."""

    def __init__(self, alloc: BlockAllocator):
        self.alloc = alloc
        self.held = {}  # owner -> List[int]
        self.next_owner = 0

    # -- operations (each mirrors one allocator call) ----------------------
    def op_alloc(self, n: int):
        owner = self.next_owner
        self.next_owner += 1
        if not self.alloc.can_alloc(n):
            with pytest.raises(MemoryError):
                self.alloc.alloc(owner, n)
            return
        pages = self.alloc.alloc(owner, n)
        assert len(pages) == n and len(set(pages)) == n
        self.held[owner] = list(pages)

    def op_fork(self, src_owner: int, k: int):
        pages = self.held[src_owner][:k]
        owner = self.next_owner
        self.next_owner += 1
        self.alloc.fork(pages, owner)
        self.held[owner] = list(pages)

    def op_cow(self, owner: int, idx: int):
        page = self.held[owner][idx]
        if self.alloc.ref_count(page) == 1:
            assert self.alloc.cow(owner, page) == page
            return
        if self.alloc.num_free == 0:
            with pytest.raises(MemoryError):
                self.alloc.cow(owner, page)
            return
        new = self.alloc.cow(owner, page)
        assert new != page and self.alloc.ref_count(new) == 1
        self.held[owner][idx] = new

    def op_free_tail(self, owner: int, k: int):
        tail = self.held[owner][-k:]
        self.alloc.free_pages(owner, tail)
        del self.held[owner][-k:]

    def op_free_request(self, owner: int):
        n = self.alloc.free_request(owner)
        assert n == len(self.held.pop(owner))

    # -- the conservation invariant ----------------------------------------
    def check(self):
        self.alloc.check()
        live = {p for pages in self.held.values() for p in pages}
        assert self.alloc.num_free + len(live) == N_PAGES
        assert self.alloc.num_in_use == len(live)
        for owner, pages in self.held.items():
            assert self.alloc.pages_of(owner) == sorted(pages), owner

    def owners_with_pages(self):
        return sorted(o for o, ps in self.held.items() if ps)


class ScaledAllocModel(AllocModel):
    """AllocModel plus a host mirror of the quantized KV *scale pool*
    (kernels/kv_quant.py): one fp32 scale per live page, born 0.0 with
    the page, copied by COW with the page's bits, released exactly when
    the last reference drops.  The invariant extends conservation to
    scales: ``set(scales) == live pages`` after every op — a scale is
    never orphaned (left behind by a free) and never double-freed
    (removing it twice raises KeyError).
    """

    def __init__(self, alloc: BlockAllocator):
        super().__init__(alloc)
        self.scales = {}  # page -> float

    def op_alloc(self, n: int):
        prev_owners = set(self.held)
        super().op_alloc(n)
        for owner in set(self.held) - prev_owners:
            for p in self.held[owner]:
                # a freshly-allocated page must not still carry a scale
                assert p not in self.scales, f"orphaned scale on page {p}"
                self.scales[p] = 0.0

    def op_cow(self, owner: int, idx: int):
        old = self.held[owner][idx]
        super().op_cow(owner, idx)
        new = self.held[owner][idx]
        if new != old:
            assert new not in self.scales, f"orphaned scale on page {new}"
            # device side: copy_pool_pages copies the scale row with
            # the page bits; the writer may then grow it monotonically
            self.scales[new] = max(self.scales[old], 0.125)

    def _release(self, pages, rc):
        for p in set(pages):
            if rc[p] == 1:  # last reference dropped -> page is free
                del self.scales[p]  # KeyError here == double-free

    def op_free_tail(self, owner: int, k: int):
        tail = list(self.held[owner][-k:])
        rc = {p: self.alloc.ref_count(p) for p in set(tail)}
        super().op_free_tail(owner, k)
        self._release(tail, rc)

    def op_free_request(self, owner: int):
        pages = list(self.held[owner])
        rc = {p: self.alloc.ref_count(p) for p in set(pages)}
        super().op_free_request(owner)
        self._release(pages, rc)

    def check(self):
        super().check()
        live = {p for pages in self.held.values() for p in pages}
        assert set(self.scales) == live, (
            f"scale pool out of sync: orphaned="
            f"{set(self.scales) - live} missing={live - set(self.scales)}"
        )


# ---------------------------------------------------------------------------
# Seeded random walk (always runs, hypothesis or not)
# ---------------------------------------------------------------------------

def _random_step(m: AllocModel, rng: np.random.Generator):
    owners = m.owners_with_pages()
    ops = ["alloc"]
    if owners:
        ops += ["fork", "cow", "free_tail", "free_request"]
    op = ops[int(rng.integers(len(ops)))]
    if op == "alloc":
        m.op_alloc(int(rng.integers(1, 5)))
    elif op == "fork":
        o = owners[int(rng.integers(len(owners)))]
        m.op_fork(o, int(rng.integers(1, len(m.held[o]) + 1)))
    elif op == "cow":
        o = owners[int(rng.integers(len(owners)))]
        m.op_cow(o, int(rng.integers(len(m.held[o]))))
    elif op == "free_tail":
        o = owners[int(rng.integers(len(owners)))]
        m.op_free_tail(o, int(rng.integers(1, len(m.held[o]) + 1)))
    else:
        o = owners[int(rng.integers(len(owners)))]
        m.op_free_request(o)


@pytest.mark.parametrize("seed", range(8))
def test_allocator_random_walk_conserves_pages(seed):
    rng = np.random.default_rng(seed)
    m = AllocModel(BlockAllocator(N_PAGES))
    for _ in range(400):
        _random_step(m, rng)
        m.check()
    for o in list(m.held):
        m.op_free_request(o)
    m.check()
    assert m.alloc.num_free == N_PAGES  # nothing leaked


@pytest.mark.parametrize("seed", range(4))
def test_scale_pool_conserved_across_fork_cow_free(seed):
    """Quantized-KV satellite: the per-page scale pool must obey the
    same conservation invariant as the data pages — a scale row exists
    iff its page is live, survives fork (shared), is copied by COW, and
    is released exactly once when the last reference drops."""
    rng = np.random.default_rng(1000 + seed)
    m = ScaledAllocModel(BlockAllocator(N_PAGES))
    for _ in range(400):
        _random_step(m, rng)
        m.check()
    for o in list(m.held):
        m.op_free_request(o)
    m.check()
    assert m.scales == {} and m.alloc.num_free == N_PAGES


def test_exclusive_tail_rollback_restores_free_list_exactly():
    """Draft-style cycles at random depths: allocating a tail and
    rolling it back must leave the free *list* (order included)
    bit-identical — on a pool already fragmented by refcounted churn."""
    rng = np.random.default_rng(123)
    m = AllocModel(BlockAllocator(N_PAGES))
    for _ in range(100):
        _random_step(m, rng)
    for _ in range(50):
        owners = m.owners_with_pages()
        if not owners or m.alloc.num_free == 0:
            _random_step(m, rng)
            continue
        o = owners[int(rng.integers(len(owners)))]
        k = int(rng.integers(1, m.alloc.num_free + 1))
        before = list(m.alloc._free)
        tail = m.alloc.alloc(o, k)
        m.alloc.free_pages(o, tail)
        assert m.alloc._free == before
        m.check()


# ---------------------------------------------------------------------------
# Hypothesis stateful machine (shrinking; >=1000 examples)
# ---------------------------------------------------------------------------

try:  # plain try/import — importorskip here would skip the walk tests too
    import hypothesis
    from hypothesis import stateful
    from hypothesis import strategies as st
except ImportError:
    hypothesis = None

if hypothesis is not None:
    class AllocatorMachine(stateful.RuleBasedStateMachine):
        def __init__(self):
            super().__init__()
            # ScaledAllocModel extends the invariant to the quantized-KV
            # scale pool: scales never orphaned or double-freed
            self.m = ScaledAllocModel(BlockAllocator(N_PAGES))

        def _pick_owner(self, data):
            owners = self.m.owners_with_pages()
            return data.draw(st.sampled_from(owners), label="owner")

        @stateful.rule(n=st.integers(min_value=1, max_value=5))
        def alloc(self, n):
            self.m.op_alloc(n)

        @stateful.precondition(lambda self: self.m.owners_with_pages())
        @stateful.rule(data=st.data())
        def fork(self, data):
            o = self._pick_owner(data)
            k = data.draw(st.integers(1, len(self.m.held[o])), label="k")
            self.m.op_fork(o, k)

        @stateful.precondition(lambda self: self.m.owners_with_pages())
        @stateful.rule(data=st.data())
        def cow(self, data):
            o = self._pick_owner(data)
            idx = data.draw(st.integers(0, len(self.m.held[o]) - 1),
                            label="idx")
            self.m.op_cow(o, idx)

        @stateful.precondition(lambda self: self.m.owners_with_pages())
        @stateful.rule(data=st.data())
        def free_tail(self, data):
            o = self._pick_owner(data)
            k = data.draw(st.integers(1, len(self.m.held[o])), label="k")
            self.m.op_free_tail(o, k)

        @stateful.precondition(lambda self: self.m.owners_with_pages())
        @stateful.rule(data=st.data())
        def free_request(self, data):
            self.m.op_free_request(self._pick_owner(data))

        @stateful.invariant()
        def conserved(self):
            self.m.check()

    # ISSUE acceptance: the conservation invariant must survive >=1000
    # hypothesis examples; the conftest ci profile pins deadline=None
    # and derandomize so this cannot flake tier-1 on slow runners
    AllocatorMachine.TestCase.settings = hypothesis.settings(
        hypothesis.settings.get_profile("ci"),
        max_examples=1000,
        stateful_step_count=25,
    )
    TestAllocatorProperties = AllocatorMachine.TestCase
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_allocator_state_machine():
        pass

"""Streamed-vs-drained identity: the async edge must not change tokens.

The frontend adds streaming, continuous batching, and an HTTP/SSE
surface around ``PagedServer`` — none of which may perturb *what* is
generated.  These tests pin that down three ways:

* **handle streams == sync drain** — tokens consumed through the
  ``StreamHandle`` async iterator, with requests arriving mid-run
  (continuous batching) and the pool sized so preemption fires, are
  token-identical to a plain synchronous ``submit/step/drain`` of the
  same trace;
* **prefix warm starts** — the same identity with the radix prefix
  cache on and a second wave of requests re-using a finished wave's
  system prefix (``prefix_hits > 0`` is asserted, so the cache provably
  engaged);
* **SSE framing == handle stream** — ``handle_connection`` driven over
  in-memory ``StreamReader``/fake-writer pipes produces exactly one
  ``data:`` frame per token, in order, equal to the deterministic
  engine stream; EOF on the read side mid-stream cancels the request
  and frees its pages.

All async driving happens inside ``asyncio.run`` on a ``FakeClock`` —
no pytest-asyncio dependency, zero wall-clock sleeps.
"""
import asyncio
import json

import jax
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.core import GriffinConfig
from repro.models import decoder
from repro.serving.clock import FakeClock
from repro.serving.frontend import (ACTIVE, CANCELLED, FINISHED,
                                    ServingFrontend)
from repro.serving.metrics import ServingMetrics
from repro.serving.server import PagedServer
from repro.serving.sim import SimServer, sim_token


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("tinylm")
    params = decoder.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _server(cfg, params, *, prefix: bool, clock, num_pages=40):
    return PagedServer(
        cfg, params, gcfg=GriffinConfig(sparsity=0.5, per_shard_topk=False),
        page_size=8, num_pages=num_pages, n_slots=2, prefill_chunk=8,
        max_len=64, spec_k=0, prefix_cache=prefix,
        metrics=ServingMetrics(clock=clock))


def _mk_trace(shared_prefix: bool, cfg):
    rng = np.random.default_rng(7)
    sys_p = rng.integers(0, cfg.vocab_size, size=24).astype(np.int32)
    out = []
    for i in range(5):
        tail = rng.integers(0, cfg.vocab_size,
                            size=int(rng.integers(4, 10))).astype(np.int32)
        if shared_prefix:
            out.append(np.concatenate([sys_p, tail]))
        else:
            out.append(rng.integers(0, cfg.vocab_size,
                                    size=int(rng.integers(12, 24))
                                    ).astype(np.int32))
    return list(zip(out, [8, 6, 10, 7, 9]))


def _oracle(cfg, params, trace, *, prefix, num_pages=40):
    srv = _server(cfg, params, prefix=prefix, clock=FakeClock(),
                  num_pages=num_pages)
    for i, (p, m) in enumerate(trace):
        srv.submit(p, m, rid=i)
    out = srv.drain()
    return {i: tuple(out[i]) for i in out}


async def _stream_all(fe, clk, handles, *, late=(), max_ticks=2000):
    """Consume every handle through its async iterator while ticking the
    frontend by hand; ``late`` is [(tick, prompt, max_new)] submissions
    that arrive mid-run (continuous batching joins them to the running
    batch)."""
    outs = {}
    tasks = {}

    def track(h):
        async def consume():
            got = []
            async for t in h:
                got.append(t)
            return got
        outs[h.rid] = h
        tasks[h.rid] = asyncio.ensure_future(consume())

    for h in handles:
        track(h)
    late = list(late)
    tick = 0
    while (not all(t.done() for t in tasks.values())) or late or fe.has_work:
        while late and late[0][0] <= tick:
            _, p, m, rid_expect = late.pop(0)
            h = fe.submit(p, m, slo="batch")
            assert h.rid == rid_expect
            track(h)
        fe.tick()
        clk.advance(0.001)
        await asyncio.sleep(0)
        tick += 1
        assert tick < max_ticks
    return {rid: tasks[rid].result() for rid in tasks}, outs


def test_streamed_tokens_match_drained_with_preemption(tiny):
    cfg, params = tiny
    trace = _mk_trace(False, cfg)
    oracle = _oracle(cfg, params, trace, prefix=False, num_pages=5)
    clk = FakeClock()
    # 5 pages * 8 tokens: any single request fits (<=5 pages) but the
    # first pair fills the pool during request 1's prefill, so request
    # 0's decode growth must preempt — later-arrival victims only, so
    # this is the earlier-grows-into-dry-pool case
    srv = _server(cfg, params, prefix=False, clock=clk, num_pages=5)
    fe = ServingFrontend(srv, queue_depth=4, clock=clk)

    async def run():
        first = [fe.submit(p, m, slo="batch") for p, m in trace[:2]]
        assert first[0].rid == 0 and first[1].rid == 1
        late = [(3 + 2 * j, p, m, 2 + j)
                for j, (p, m) in enumerate(trace[2:])]
        return await _stream_all(fe, clk, first, late=late)

    streamed, handles = asyncio.run(run())
    assert srv.metrics.preemptions > 0, "pool sizing no longer forces preemption"
    for i in range(len(trace)):
        assert handles[i].state == FINISHED
        assert tuple(streamed[i]) == oracle[i], f"stream {i} diverged"
        assert tuple(handles[i].tokens) == oracle[i]


def test_streamed_tokens_match_drained_with_prefix_warm_start(tiny):
    cfg, params = tiny
    trace = _mk_trace(True, cfg)
    oracle = _oracle(cfg, params, trace, prefix=True)
    clk = FakeClock()
    srv = _server(cfg, params, prefix=True, clock=clk)
    fe = ServingFrontend(srv, queue_depth=4, clock=clk)

    async def run():
        # wave 1 populates the radix cache with the shared system prefix
        wave1 = [fe.submit(p, m, slo="batch") for p, m in trace[:2]]
        out1, h1 = await _stream_all(fe, clk, wave1)
        # wave 2 re-uses it: warm starts against retained cache pages
        wave2 = [fe.submit(p, m, slo="batch") for p, m in trace[2:]]
        out2, h2 = await _stream_all(fe, clk, wave2)
        out1.update(out2)
        h1.update(h2)
        return out1, h1

    streamed, handles = asyncio.run(run())
    assert srv.metrics.prefix_hits > 0, "warm starts never hit the cache"
    for i in range(len(trace)):
        assert handles[i].state == FINISHED
        assert tuple(streamed[i]) == oracle[i], f"stream {i} diverged"


# ---------------------------------------------------------------------------
# SSE framing over in-memory pipes (SimServer: framing is engine-agnostic)
# ---------------------------------------------------------------------------

class _MemWriter:
    """Capture-only StreamWriter stand-in for handler tests."""

    def __init__(self):
        self.buf = bytearray()
        self.closed = False

    def write(self, b: bytes) -> None:
        self.buf.extend(b)

    async def drain(self) -> None:
        pass

    def can_write_eof(self) -> bool:
        return False

    def close(self) -> None:
        self.closed = True


def _post(path: str, obj) -> bytes:
    body = json.dumps(obj).encode()
    return (f"POST {path} HTTP/1.1\r\nContent-Length: {len(body)}\r\n"
            f"\r\n").encode() + body


def _parse_sse(raw: bytes):
    """-> (status_line, [(event_or_None, data_dict), ...])"""
    head, _, body = raw.partition(b"\r\n\r\n")
    status = head.split(b"\r\n")[0].decode()
    frames = []
    for chunk in body.decode().split("\n\n"):
        if not chunk.strip():
            continue
        event, data = None, None
        for line in chunk.splitlines():
            if line.startswith("event: "):
                event = line[len("event: "):]
            elif line.startswith("data: "):
                data = json.loads(line[len("data: "):])
        frames.append((event, data))
    return status, frames


def _sim_frontend(**kw):
    clk = FakeClock()
    srv = SimServer(metrics=ServingMetrics(clock=clk), **kw)
    fe = ServingFrontend(srv, clock=clk)
    return fe, srv, clk


async def _drive_handler(fe, clk, reader, writer, *, max_ticks=500,
                         mid=None):
    task = asyncio.ensure_future(fe.handle_connection(reader, writer))
    tick = 0
    while not task.done():
        if mid is not None and tick == mid[0]:
            mid[1]()
        fe.tick()
        clk.advance(0.001)
        await asyncio.sleep(0)
        tick += 1
        assert tick < max_ticks
    await task


def test_sse_stream_equals_engine_stream():
    fe, srv, clk = _sim_frontend()
    max_new = 9
    writer = _MemWriter()

    async def run():
        # StreamReader must be born inside the running loop (3.10)
        reader = asyncio.StreamReader()
        reader.feed_data(_post("/v1/generate",
                               {"prompt": [1, 2, 3], "max_new": max_new,
                                "slo": "interactive"}))
        await _drive_handler(fe, clk, reader, writer)

    asyncio.run(run())
    status, frames = _parse_sse(bytes(writer.buf))
    assert status == "HTTP/1.1 200 OK"
    assert frames[0][0] == "accepted" and frames[0][1]["slo"] == "interactive"
    rid = frames[0][1]["rid"]
    tokens = [d["token"] for ev, d in frames[1:-1]]
    # one frame per token, in order, equal to the deterministic engine
    # stream — SSE adds framing, never reorders or drops
    assert tokens == [sim_token(rid, p) for p in range(max_new)]
    done_ev, done = frames[-1]
    assert done_ev == "done"
    assert done["reason"] == "complete" and done["tokens"] == max_new
    assert done["slo_met"] is True
    assert writer.closed


def test_sse_disconnect_cancels_and_frees_pages():
    fe, srv, clk = _sim_frontend(num_pages=16)
    writer = _MemWriter()

    async def run():
        reader = asyncio.StreamReader()
        reader.feed_data(_post("/v1/generate",
                               {"prompt": list(range(8)), "max_new": 32}))
        # EOF on the client pipe a few ticks in — mid-decode — cancels
        await _drive_handler(fe, clk, reader, writer,
                             mid=(4, reader.feed_eof))

    asyncio.run(run())
    h = fe.handles[0]
    assert h.state in (ACTIVE, CANCELLED)  # cancel applies at next tick
    fe.run_until_idle()
    assert h.state == CANCELLED
    assert 0 < len(h.tokens) < 32
    srv.sched.alloc.check()
    assert srv.sched.alloc.num_in_use == 0
    assert srv.metrics.cancelled_aborts == 1
    assert srv.metrics.cancel_latency.count == 1


def test_http_surface_statuses():
    fe, srv, clk = _sim_frontend()

    async def roundtrip(raw: bytes) -> bytes:
        reader = asyncio.StreamReader()
        reader.feed_data(raw)
        writer = _MemWriter()
        await _drive_handler(fe, clk, reader, writer)
        return bytes(writer.buf)

    async def run():
        health = await roundtrip(b"GET /healthz HTTP/1.1\r\n\r\n")
        bad = await roundtrip(_post("/v1/generate", {"max_new": 4}))
        too_long = await roundtrip(_post("/v1/generate",
                                         {"prompt": [1] * 1000,
                                          "max_new": 4}))
        lost = await roundtrip(b"GET /nope HTTP/1.1\r\n\r\n")
        metrics = await roundtrip(b"GET /metrics HTTP/1.1\r\n\r\n")
        return health, bad, too_long, lost, metrics

    health, bad, too_long, lost, metrics = asyncio.run(run())
    assert health.startswith(b"HTTP/1.1 200") and b'"ok": true' in health
    assert bad.startswith(b"HTTP/1.1 400")
    assert too_long.startswith(b"HTTP/1.1 400")
    assert lost.startswith(b"HTTP/1.1 404")
    assert metrics.startswith(b"HTTP/1.1 200")
    assert b"frontend_requests_total" in metrics


def test_http_backpressure_429():
    fe, srv, clk = _sim_frontend()
    fe.max_pending = 2
    for _ in range(2):
        fe.submit(np.asarray([1, 2], np.int32), 4)

    async def run():
        reader = asyncio.StreamReader()
        reader.feed_data(_post("/v1/generate",
                               {"prompt": [1, 2], "max_new": 4}))
        writer = _MemWriter()
        # respond-then-close happens before any tick is needed
        await fe.handle_connection(reader, writer)
        return bytes(writer.buf)

    raw = asyncio.run(run())
    assert raw.startswith(b"HTTP/1.1 429")
    assert fe._c_rejected.value == 1
    fe.run_until_idle()  # the two accepted requests still finish
    assert all(h.state == FINISHED for h in fe.handles.values())

"""Analysis-stack tests: HLO collective parser, roofline math, analytic
cost model, report plumbing, eval protocols."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import analytic, hlo, roofline
from repro.configs.registry import ASSIGNED_ARCHS, get_config
from repro.configs.shapes import SHAPES, cell_supported


# ---------------------------------------------------------------------------
# HLO parser
# ---------------------------------------------------------------------------

HLO_SAMPLE = """
ENTRY %main {
  %x = bf16[128,256]{1,0} parameter(0)
  %ar = bf16[128,256]{1,0} all-reduce(%x), replica_groups={{0,1,2,3},{4,5,6,7}}
  %ag = f32[64,512]{1,0} all-gather(%x), replica_groups={{0,1}}, dimensions={0}
  %rs = f32[32,512]{1,0} reduce-scatter(%ag), replica_groups={{0,1}}
  %cp = s8[1024]{0} collective-permute(%x), source_target_pairs={{0,1}}
  %dot = f32[128,128]{1,0} dot(%x, %x)
}
"""


def test_collective_bytes_parser():
    out = hlo.collective_bytes(HLO_SAMPLE, total_devices=8)
    # all-reduce: 128*256*2 bytes * 2 * (4-1)/4
    ar = 128 * 256 * 2
    assert abs(out["bytes_all-reduce"] - 2 * ar * 3 / 4) < 1
    # all-gather: 64*512*4 * (2-1)/2
    ag = 64 * 512 * 4
    assert abs(out["bytes_all-gather"] - ag / 2) < 1
    # reduce-scatter: out bytes * (n-1)
    rs = 32 * 512 * 4
    assert abs(out["bytes_reduce-scatter"] - rs) < 1
    assert out["bytes_collective-permute"] == 1024
    assert out["count_all-reduce"] == 1
    assert out["bytes_total"] > 0


def test_count_ops():
    c = hlo.count_ops(HLO_SAMPLE)
    assert c["dot"] == 1


# ---------------------------------------------------------------------------
# Roofline math
# ---------------------------------------------------------------------------

def test_roofline_terms_and_dominance():
    r = roofline.from_costs(
        flops_per_chip=197e12,  # exactly 1 s of compute
        hbm_bytes_per_chip=819e9 / 2,  # 0.5 s
        collective_bytes_per_chip=50e9 / 4,  # 0.25 s
        model_flops_total=197e12 * 256,
        chips=256,
    )
    assert abs(r.compute_s - 1.0) < 1e-9
    assert r.dominant == "compute"
    assert abs(r.useful_ratio - 1.0) < 1e-9
    assert abs(r.roofline_fraction - 1.0) < 1e-9


def test_param_counts_match_spec_tree():
    """Analytic active <= total; MoE total >> active."""
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        n = roofline.count_params(cfg)
        assert n["active"] <= n["total"]
        if cfg.num_experts:
            assert n["active"] < 0.5 * n["total"], arch


def test_analytic_cell_costs_positive_all_cells():
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        for sname, shape in SHAPES.items():
            ok, _ = cell_supported(cfg, shape)
            if not ok:
                continue
            c = analytic.cell_cost(cfg, shape)
            assert c.flops > 0 and c.hbm_bytes > 0, (arch, sname)
            if shape.kind == "decode":
                cg = analytic.cell_cost(cfg, shape, griffin_sparsity=0.5)
                if cfg.griffin and cfg.has_ffn:
                    assert cg.flops < c.flops, (arch, sname)
                    assert cg.param_bytes < c.param_bytes


def test_griffin_roofline_regime_shift():
    """The paper's regime (B=1, short ctx) is weight-dominated; the big
    decode_32k shape is cache-dominated — the EXPERIMENTS.md section
    Roofline regime-shift claim."""
    from repro.configs.shapes import ShapeConfig

    cfg = get_config("yi-9b")
    small = ShapeConfig("paper", 4096, 1, "decode")
    big = SHAPES["decode_32k"]
    cs = analytic.cell_cost(cfg, small)
    cb = analytic.cell_cost(cfg, big)
    assert cs.param_bytes > cs.cache_bytes  # paper regime
    assert cb.cache_bytes > 10 * cb.param_bytes  # large-batch long-ctx


# ---------------------------------------------------------------------------
# Eval protocols (on an untrained tiny model: structural checks)
# ---------------------------------------------------------------------------

def test_generation_ppl_full_equals_griffin_at_zero_sparsity(rng):
    from repro.core import evaluate
    from repro.models import decoder

    cfg = get_config("tinylm").replace(num_layers=2, d_model=64, d_ff=128,
                                       num_heads=4, num_kv_heads=2, head_dim=16)
    params = decoder.init_params(cfg, rng)
    toks = jax.random.randint(rng, (2, 48), 0, cfg.vocab_size)
    p_full = evaluate.generation_ppl(params, cfg, toks, 32, "full")
    p_g0 = evaluate.generation_ppl(params, cfg, toks, 32, "griffin", 0.0)
    assert abs(p_full - p_g0) / p_full < 1e-4
    p_g5 = evaluate.generation_ppl(params, cfg, toks, 32, "griffin", 0.5)
    assert np.isfinite(p_g5) and p_g5 > 0


def test_classification_sim_protocol(rng):
    from repro.core import evaluate
    from repro.models import decoder

    cfg = get_config("tinylm").replace(num_layers=2, d_model=64, d_ff=128,
                                       num_heads=4, num_kv_heads=2, head_dim=16)
    params = decoder.init_params(cfg, rng)
    toks = jax.random.randint(rng, (4, 32), 0, cfg.vocab_size)
    r = evaluate.classification_sim(params, cfg, toks, "griffin", 0.0)
    assert r["agree_full"] == 1.0  # zero sparsity: identical predictions
    r5 = evaluate.classification_sim(params, cfg, toks, "magnitude", 0.5)
    assert 0.0 <= r5["agree_full"] <= 1.0


def test_dcn_classification():
    txt = """
ENTRY %e {
  %x = bf16[64]{0} parameter(0)
  %a = bf16[64]{0} all-reduce(%x), replica_groups={{0,256}}
  %b = bf16[64]{0} all-reduce(%x), replica_groups={{0,1,2,3}}
}
"""
    out = hlo.collective_bytes(txt, 512, pod_size=256)
    assert out["bytes_dcn"] > 0
    assert out["bytes_ici"] > 0
    assert abs(out["bytes_dcn"] + out["bytes_ici"] - out["bytes_total"]) < 1e-6

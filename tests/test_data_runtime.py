"""Data pipeline determinism/sharding + straggler/elastic runtime."""
import numpy as np
import pytest

from repro.data.pipeline import (
    MemmapCorpus,
    ShardedLoader,
    SyntheticCorpus,
    write_memmap_corpus,
)
from repro.data.tokenizer import ByteTokenizer
from repro.runtime.elastic import plan_remesh
from repro.runtime.straggler import StragglerDetector


def test_tokenizer_roundtrip():
    tok = ByteTokenizer()
    s = "hello GRIFFIN — ascii & unicode"
    assert tok.decode(tok.encode(s)) == s


def test_synthetic_corpus_deterministic():
    c = SyntheticCorpus(seed=3)
    a = c.sample(100, seed=5)
    b = c.sample(100, seed=5)
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, c.sample(100, seed=6))


def test_synthetic_corpus_learnable_structure():
    """The Markov chain must be peaky (low entropy) so tiny LMs learn it."""
    c = SyntheticCorpus(seed=0)
    x = c.sample(5000, seed=1)
    _, counts = np.unique(x, return_counts=True)
    p = counts / counts.sum()
    ent = -(p * np.log(p)).sum()
    assert ent < 4.0  # far below uniform ln(256) = 5.55


def test_loader_deterministic_and_host_disjoint():
    c = SyntheticCorpus(seed=0)
    l0 = ShardedLoader(c, batch=2, seq_len=16, seed=1, host_id=0, n_hosts=2)
    l0b = ShardedLoader(c, batch=2, seq_len=16, seed=1, host_id=0, n_hosts=2)
    l1 = ShardedLoader(c, batch=2, seq_len=16, seed=1, host_id=1, n_hosts=2)
    b0, b0b, b1 = next(l0), next(l0b), next(l1)
    for l in (l0, l0b, l1):
        l.close()
    np.testing.assert_array_equal(b0["tokens"], b0b["tokens"])
    assert not np.array_equal(b0["tokens"], b1["tokens"])


def test_memmap_corpus(tmp_path):
    path = str(tmp_path / "corpus.bin")
    write_memmap_corpus(path, np.arange(1000))
    c = MemmapCorpus(path)
    w = c.window(10, 20)
    np.testing.assert_array_equal(w, np.arange(10, 30))


def test_straggler_detection():
    det = StragglerDetector(threshold=1.5, patience=2)
    for step in range(5):
        for host in range(8):
            det.record(host, 1.0 if host != 3 else 2.5)
        flagged = det.evaluate()
    assert flagged == {3}


def test_straggler_recovery_clears_strikes():
    det = StragglerDetector(threshold=1.5, patience=3)
    for host in range(4):
        det.record(host, 1.0)
    det.record(0, 5.0)
    det.evaluate()
    for _ in range(30):  # EWMA converges back to normal
        det.record(0, 1.0)
        for host in range(1, 4):
            det.record(host, 1.0)
        flagged = det.evaluate()
    assert flagged == set()


def test_remesh_plan():
    plan = plan_remesh((2, 16, 16), ("pod", "data", "model"), failed_data_rows=[3, 7])
    assert plan.new_shape == (2, 14, 16)
    assert plan.global_batch_scale == 14 / 16
    with pytest.raises(RuntimeError):
        plan_remesh((1, 16), ("data", "model"), list(range(16)))
